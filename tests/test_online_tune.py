"""PR 8 online-tuning acceptance: the dispatcher-knob model, the dtype
policy guard (a too-lossy policy is REJECTED, never silently kept), the
per-bucket online tuner's disk round-trip, and the self-tuning
CurvatureService -- a traffic shift must trigger a re-tune whose winner is
hot-swapped with every in-flight future still resolving, and per-request
diag probe budgets must coalesce exactly."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import engine
from repro.core import testfns
from repro.engine import opmodel, registry
from repro.engine.autotune import (BucketTunedConfig, DtypePolicyRejected,
                                   apply_bucket_config, autotune_buckets,
                                   verify_dtype_policy)
from repro.engine.service import CurvatureService

N = 8


@pytest.fixture(autouse=True)
def _clean_state():
    engine.clear_autotune_cache()
    engine.clear_telemetry()
    yield
    engine.clear_autotune_cache()
    engine.clear_telemetry()


def _flat_plan(csize=2, **opts):
    return engine.plan(testfns.rosenbrock, N, csize=csize, symmetric=False,
                       options=opts or None)


# ---------------------------------------------------------------------------
# dispatcher-knob model (opmodel.suggest_dispatch_knobs)
# ---------------------------------------------------------------------------

def test_knob_model_picks_cheapest_feasible_bucket():
    # at 1000 req/s filling 64 takes 63ms >> 5ms cap; 8 takes 7ms > cap;
    # 4 takes 3ms -- the cheapest bucket inside the cap wins
    us = {4: 2.0, 8: 1.0, 64: 0.5}
    knobs = opmodel.suggest_dispatch_knobs(1000.0, us, wait_cap_us=5000.0)
    assert knobs == (4, pytest.approx(1.5 * 3000.0))


def test_knob_model_prefers_cheaper_us_when_both_feasible():
    us = {4: 2.0, 8: 1.0}
    b, wait = opmodel.suggest_dispatch_knobs(100000.0, us,
                                             wait_cap_us=5000.0)
    assert b == 8                      # 70us fill, cheaper per point
    assert wait == pytest.approx(1.5 * 70.0)


def test_knob_model_overload_falls_back_to_smallest_bucket():
    # 1 req/s: even bucket 4 takes 3s to fill -- serve the smallest
    # measured bucket rather than holding requests past any cap
    b, wait = opmodel.suggest_dispatch_knobs(1.0, {4: 2.0, 8: 1.0},
                                             wait_cap_us=5000.0)
    assert b == 4
    assert wait <= 5000.0


def test_knob_model_nothing_to_learn_returns_none():
    assert opmodel.suggest_dispatch_knobs(None, {4: 1.0}) is None
    assert opmodel.suggest_dispatch_knobs(0.0, {4: 1.0}) is None
    assert opmodel.suggest_dispatch_knobs(100.0, {}) is None
    assert opmodel.suggest_dispatch_knobs(100.0, {4: None}) is None


# ---------------------------------------------------------------------------
# dtype policy: tunable, oracle-guarded, rejected when too lossy
# ---------------------------------------------------------------------------

def test_fp32_policy_is_exact_and_free():
    assert verify_dtype_policy(_flat_plan()) == 0.0


def test_bf16_policy_verifies_within_default_tol():
    p = _flat_plan(dtype_policy="bf16")
    err = verify_dtype_policy(p)
    assert 0.0 < err < 5e-2
    # and the policy actually runs: output dtype stays the input dtype
    A = np.random.RandomState(0).uniform(-2, 2, (4, N)).astype(np.float32)
    V = np.random.RandomState(1).randn(4, N).astype(np.float32)
    out = p.batched_hvp(jnp.asarray(A), jnp.asarray(V))
    assert out.dtype == jnp.float32
    ref = _flat_plan().batched_hvp(jnp.asarray(A), jnp.asarray(V))
    err_vs_fp32 = (np.linalg.norm(np.asarray(out) - np.asarray(ref))
                   / max(np.linalg.norm(np.asarray(ref)), 1e-30))
    assert err_vs_fp32 < 5e-2


def test_bf16_policy_rejected_when_over_tol():
    """The acceptance gate of the PR: a lossy policy whose oracle error
    exceeds the plan tolerance must raise, not silently serve."""
    p = _flat_plan(dtype_policy="bf16", dtype_tol=1e-9)
    with pytest.raises(DtypePolicyRejected):
        verify_dtype_policy(p)
    # non-raising form still reports the error for the tuner's logbook
    err = verify_dtype_policy(p, raise_on_reject=False)
    assert err > 1e-9


def test_autotuner_drops_rejected_policy_and_keeps_fp32():
    cfgs = autotune_buckets(
        testfns.rosenbrock, N, [4], symmetric=False,
        options={"dtype_tol": 1e-12}, reps=1, use_store=False)
    cfg = cfgs[4]
    assert cfg.dtype_policy == "fp32"
    assert any(pol == "bf16" for pol, _err in cfg.rejected)


def test_pinned_bad_policy_raises():
    with pytest.raises(DtypePolicyRejected):
        autotune_buckets(
            testfns.rosenbrock, N, [4], symmetric=False,
            options={"dtype_policy": "bf16", "dtype_tol": 1e-12},
            reps=1, use_store=False)


def test_backends_without_policy_support_are_vetoed():
    """reference declares no dtype_policies -> it may not serve a bf16
    plan; resolution must land on a policy-capable vmap backend."""
    p = _flat_plan(dtype_policy="bf16")
    assert p.backend_for("batched_hvp").startswith("vmap_")
    with pytest.raises(Exception):
        engine.plan(testfns.rosenbrock, N, csize=2, backend="reference",
                    options={"dtype_policy": "bf16"}).executable("hvp")


# ---------------------------------------------------------------------------
# autotune_buckets: per-bucket winners, disk round-trip
# ---------------------------------------------------------------------------

def test_autotune_buckets_sweeps_observed_shapes_and_persists():
    import sys
    at = sys.modules["repro.engine.autotune"]
    cfgs = autotune_buckets(testfns.rosenbrock, N, {2: 0.3, 8: 0.7},
                            symmetric=False, reps=1)
    assert set(cfgs) == {2, 8}
    for b, cfg in cfgs.items():
        assert cfg.bucket == b and cfg.us_per_point > 0
        assert cfg.source in ("sweep", "disk")
    before = at.probe_count()
    again = autotune_buckets(testfns.rosenbrock, N, {2: 0.3, 8: 0.7},
                             symmetric=False, reps=1)
    assert at.probe_count() == before          # warm store: zero probes
    assert all(c.source == "disk" for c in again.values())
    assert {b: (c.csize, c.backend) for b, c in again.items()} == \
           {b: (c.csize, c.backend) for b, c in cfgs.items()}


def test_apply_bucket_config_reproduces_probe_cache_key():
    """Hot-swap zero-latency contract: the derived plan's cache key must
    equal the tuner's probe plan key, so the winner executable is already
    compiled at the serving shape."""
    base = _flat_plan()
    cfg = BucketTunedConfig(bucket=4, csize=4, backend="vmap_l2",
                            blk_m=None, dtype_policy="fp32",
                            us_per_point=1.0, source="measured")
    ep = apply_bucket_config(base, cfg)
    assert ep.csize == 4 and ep.backend == "vmap_l2"
    probe = engine.plan(testfns.rosenbrock, N, csize=4, symmetric=False,
                        backend="vmap_l2")
    assert ep.cache_key("batched_hvp", "vmap_l2") == \
        probe.cache_key("batched_hvp", "vmap_l2")


# ---------------------------------------------------------------------------
# the self-tuning service (fake clock, injected tuner: fully deterministic)
# ---------------------------------------------------------------------------

def _fake_tuner(calls, csize=4):
    def tuner(plan, workload, buckets, force, deadline_s):
        calls.append((dict(buckets), force))
        return {b: BucketTunedConfig(
            bucket=b, csize=csize, backend="vmap_l2", blk_m=None,
            dtype_policy="fp32", us_per_point=1e6, source="fake")
            for b in buckets}
    return tuner


def _drive(svc, p, batch, rounds, now, rng):
    futs = []
    for _ in range(rounds):
        A = rng.standard_normal((batch, N)).astype(np.float32)
        V = rng.standard_normal((batch, N)).astype(np.float32)
        futs += [(svc.submit(p, A[i], V[i]), A[i], V[i])
                 for i in range(batch)]
        now[0] += 0.01
        svc.flush()
    return futs


def test_service_retunes_on_traffic_shift_and_winner_changes():
    """The satellite scenario: steady bucket-4 traffic is tuned once; the
    mix shifts to bucket 8 -> the NEXT retune pass sweeps bucket 8 only
    (the tuned bucket-4 winner is kept), the new winner is installed, and
    every future -- including ones queued across the swap -- resolves to
    the correct HVP."""
    p = _flat_plan()
    now, calls = [0.0], []
    rng = np.random.default_rng(0)
    svc = CurvatureService(max_batch=8, max_wait_us=100.0,
                           clock=lambda: now[0], start=False,
                           tuner=_fake_tuner(calls), retune_min_points=8,
                           tune_dispatch=False)
    futs = _drive(svc, p, 4, 4, now, rng)
    s1 = svc.retune()
    assert s1 == {"queues_examined": 1, "queues_tuned": 1,
                  "hot_swaps": 1, "errors": 0}
    assert calls[-1] == ({4: 1.0}, False)
    q = list(svc._queues.values())[0]
    assert q.exec_by_bucket[4][0].csize == 4     # winner installed

    # shift the mix; queue some requests BEFORE the retune pass so the
    # swap happens with work in flight
    futs += _drive(svc, p, 8, 3, now, rng)
    A = rng.standard_normal((8, N)).astype(np.float32)
    V = rng.standard_normal((8, N)).astype(np.float32)
    inflight = [(svc.submit(p, A[i], V[i]), A[i], V[i]) for i in range(8)]
    s2 = svc.retune()
    assert calls[-1][0] == {8: 1.0}              # only the new bucket swept
    assert s2["hot_swaps"] == 1
    assert q.exec_by_bucket[8][0].csize == 4
    svc.flush()                                   # in-flight work dispatches
    futs += inflight

    # stable traffic, tuned bucket, no drift: the pass is a no-op sweep
    futs += _drive(svc, p, 8, 4, now, rng)
    s3 = svc.retune()
    assert s3["hot_swaps"] == 0 and len(calls) == 2

    for fut, a, v in futs:
        np.testing.assert_allclose(fut.result(timeout=30),
                                   np.asarray(p.hvp(a, v)),
                                   rtol=1e-4, atol=1e-5)
    assert svc.stats()["retunes"] == 3
    svc.shutdown()


def test_service_drift_forces_a_retune():
    p = _flat_plan()
    now, calls = [0.0], []
    rng = np.random.default_rng(1)
    svc = CurvatureService(max_batch=8, max_wait_us=100.0,
                           clock=lambda: now[0], start=False,
                           tuner=_fake_tuner(calls), retune_min_points=8,
                           drift_factor=1.5, tune_dispatch=False)
    for fut, a, v in _drive(svc, p, 8, 4, now, rng):
        fut.result(30)
    svc.retune()
    q = list(svc._queues.values())[0]
    # shrink the learned baseline below the measured us/point: the next
    # pass must see recent mean > drift_factor x baseline and force-probe
    q.tuned_us[8] = 1e-3
    for fut, a, v in _drive(svc, p, 8, 4, now, rng):
        fut.result(30)
    svc.retune()
    assert calls[-1] == ({8: 1.0}, True)
    svc.shutdown()


def test_service_fits_dispatch_knobs_from_rate_and_telemetry():
    p = _flat_plan()
    now, calls = [0.0], []
    rng = np.random.default_rng(2)
    svc = CurvatureService(max_batch=256, max_wait_us=100.0,
                           clock=lambda: now[0], start=False,
                           tuner=_fake_tuner(calls), retune_min_points=8,
                           tune_dispatch=True)
    for _ in range(4):                       # 10k req/s at bucket 8
        A = rng.standard_normal((8, N)).astype(np.float32)
        V = rng.standard_normal((8, N)).astype(np.float32)
        fs = [svc.submit(p, A[i], V[i]) for i in range(8)]
        now[0] += 8e-4
        svc.flush()
        for f in fs:
            f.result(30)
    svc.retune()
    rep = svc.tuning_report()
    assert rep and rep[0]["max_batch"] == 8
    assert rep[0]["max_wait_us"] is not None
    assert 8 in rep[0]["buckets"]
    svc.shutdown()


def test_retune_with_real_tuner_end_to_end():
    """No injected tuner: the service sweeps its own observed buckets with
    autotune_buckets, installs real winners, and post-swap dispatches
    still match the oracle."""
    p = _flat_plan()
    now = [0.0]
    rng = np.random.default_rng(3)
    svc = CurvatureService(max_batch=8, max_wait_us=100.0,
                           clock=lambda: now[0], start=False,
                           retune_min_points=8, retune_deadline_s=2.0,
                           tune_dispatch=False)
    for fut, a, v in _drive(svc, p, 4, 4, now, rng):
        fut.result(60)
    summary = svc.retune()
    assert summary["queues_tuned"] == 1
    rep = svc.tuning_report()[0]
    assert 4 in rep["buckets"] and rep["buckets"][4]["tuned_us"] > 0
    for fut, a, v in _drive(svc, p, 4, 2, now, rng):
        np.testing.assert_allclose(fut.result(60), np.asarray(p.hvp(a, v)),
                                   rtol=1e-4, atol=1e-5)
    svc.shutdown()


def test_pytree_and_mesh_queues_are_not_tuned():
    def loss(params):
        return jnp.sum(params["w"] ** 2) * jnp.sum(jnp.sin(params["b"]))
    params = {"w": jnp.ones((3,), jnp.float32),
              "b": jnp.ones((2,), jnp.float32)}
    p = engine.plan(loss, None)
    now, calls = [0.0], []
    svc = CurvatureService(max_batch=8, max_wait_us=100.0,
                           clock=lambda: now[0], start=False,
                           tuner=_fake_tuner(calls), retune_min_points=1)
    for _ in range(8):
        svc.submit(p, params, params)
        svc.flush()
    summary = svc.retune()
    assert summary["queues_examined"] == 0 and not calls
    svc.shutdown()


# ---------------------------------------------------------------------------
# per-request probe budgets (GGN/Hutchinson diag batching)
# ---------------------------------------------------------------------------

def _tree_point(seed=0):
    rs = np.random.RandomState(seed)
    return {"w": jnp.asarray(rs.randn(3, 3), jnp.float32),
            "b": jnp.asarray(rs.randn(3), jnp.float32)}


def _tree_loss(params):
    w, b = params["w"], params["b"]
    return jnp.sum((w @ w.T + b) ** 2) + jnp.sum(jnp.sin(b))


def test_diag_probe_budgets_coalesce_into_one_bucket():
    """Mixed budgets share one compiled program: a full-budget row equals
    plan.diag EXACTLY; a budgeted row equals the direct budgeted estimate
    over the same key-derived probe prefix."""
    from repro.core.curvature import hutchinson_diag_budgeted
    p = engine.plan(_tree_loss, None, csize=4, options={"n_probes": 8})
    params = _tree_point()
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    with CurvatureService(max_batch=8, max_wait_us=100.0,
                          start=False) as svc:
        f_full = svc.submit(p, params, k1, workload="diag")
        f_two = svc.submit(p, params, k2, workload="diag", n_probes=2)
        f_cap = svc.submit(p, params, k3, workload="diag", n_probes=8)
        svc.flush()
        assert svc.stats()["batches"] == 1          # ONE coalesced bucket
        r_full, r_two, r_cap = (f_full.result(60), f_two.result(60),
                                f_cap.result(60))
    for got, want in ((r_full, p.diag(params, k1)),
                      (r_cap, p.diag(params, k3))):
        for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
            np.testing.assert_allclose(a, np.asarray(b),
                                       rtol=1e-4, atol=1e-5)
    want_two = hutchinson_diag_budgeted(_tree_loss, params, k2, 2,
                                        n_probes=8, csize=4)
    for a, b in zip(jax.tree.leaves(r_two), jax.tree.leaves(want_two)):
        np.testing.assert_allclose(a, np.asarray(b), rtol=1e-4, atol=1e-5)


def test_ggn_diag_budgeted_submits():
    from repro.core.curvature import ggn_diag_budgeted

    def model_fn(params):
        return params["w"] @ jnp.ones((3,), jnp.float32) + params["b"]

    def head_loss(z):
        return jnp.sum(jnp.log1p(z ** 2))

    p = engine.plan(lambda q: head_loss(model_fn(q)), None, csize=4,
                    options={"n_probes": 8, "diag_of": "ggn",
                             "model_fn": model_fn, "head_loss": head_loss})
    params = _tree_point(1)
    k1, k2 = jax.random.split(jax.random.PRNGKey(7))
    with CurvatureService(max_batch=8, max_wait_us=100.0,
                          start=False) as svc:
        f_full = svc.submit(p, params, k1, workload="diag")
        f_half = svc.submit(p, params, k2, workload="diag", n_probes=4)
        svc.flush()
        r_full, r_half = f_full.result(60), f_half.result(60)
    for a, b in zip(jax.tree.leaves(r_full),
                    jax.tree.leaves(p.diag(params, k1))):
        np.testing.assert_allclose(a, np.asarray(b), rtol=1e-4, atol=1e-5)
    want = ggn_diag_budgeted(model_fn, head_loss, params, k2, 4,
                             n_probes=8, csize=4)
    for a, b in zip(jax.tree.leaves(r_half), jax.tree.leaves(want)):
        np.testing.assert_allclose(a, np.asarray(b), rtol=1e-4, atol=1e-5)


def test_probe_budget_validation():
    p = engine.plan(_tree_loss, None, csize=4, options={"n_probes": 8})
    params = _tree_point()
    key = jax.random.PRNGKey(0)
    flat = _flat_plan()
    with CurvatureService(start=False) as svc:
        with pytest.raises(ValueError, match="out of range"):
            svc.submit(p, params, key, workload="diag", n_probes=9)
        with pytest.raises(ValueError, match="out of range"):
            svc.submit(p, params, key, workload="diag", n_probes=0)
        with pytest.raises(ValueError, match="probe"):
            svc.submit(p, params, params, n_probes=2)    # hvp submit
        with pytest.raises(ValueError, match="probe"):
            svc.submit(flat, np.zeros(N, np.float32),
                       np.zeros(N, np.float32), n_probes=2)
        svc.flush()
