"""int8 KV cache: quantization round-trip error bounds and end-to-end decode
logit drift vs the bf16 cache."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.attention import decode_attention
from repro.models.kv_quant import (cache_read_quant, cache_write_one_quant,
                                   dequantize_kv, init_quant_attn_cache,
                                   quantize_kv)


def test_roundtrip_error_bound():
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(4, 16, 8, 64) * 3.0, jnp.float32)
    q, s = quantize_kv(x)
    back = dequantize_kv(q, s, jnp.float32)
    # symmetric int8: max error = scale/2 = max|x|/254 per (pos, head)
    bound = (jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 254.0 + 1e-6)
    assert bool(jnp.all(jnp.abs(back - x) <= bound + 1e-5))


def test_decode_attention_with_quant_cache_close():
    from repro.configs import get_config
    cfg = get_config("qwen1.5-4b", reduced=True)
    rng = np.random.RandomState(1)
    B, C, KV, hd, H = 2, 32, cfg.num_kv_heads, cfg.head_dim_, cfg.num_heads

    qcache = init_quant_attn_cache(cfg, B, C)
    fcache_k = jnp.zeros((B, C, KV, hd), jnp.float32)
    fcache_v = jnp.zeros((B, C, KV, hd), jnp.float32)
    pos_arr = jnp.full((B, C), -1, jnp.int32)

    for t in range(16):
        k1 = jnp.asarray(rng.randn(B, 1, KV, hd), jnp.float32)
        v1 = jnp.asarray(rng.randn(B, 1, KV, hd), jnp.float32)
        pos = jnp.full((B,), t, jnp.int32)
        qcache = cache_write_one_quant(qcache, k1, v1, pos)
        fcache_k = fcache_k.at[:, t].set(k1[:, 0])
        fcache_v = fcache_v.at[:, t].set(v1[:, 0])
        pos_arr = pos_arr.at[:, t].set(t)

    q = jnp.asarray(rng.randn(B, 1, H, hd), jnp.float32)
    cur = jnp.full((B,), 15, jnp.int32)
    kq, vq = cache_read_quant(qcache, jnp.float32)
    out_q = decode_attention(q, kq, vq, qcache["pos"], cur)
    out_f = decode_attention(q, fcache_k, fcache_v, pos_arr, cur)
    err = float(jnp.abs(out_q - out_f).max())
    assert err < 0.05, err  # ~1% of unit-scale values


def test_memory_halves():
    from repro.configs import get_config
    cfg = get_config("qwen1.5-4b", reduced=True)
    B, C = 2, 128
    qc = init_quant_attn_cache(cfg, B, C)
    bytes_q = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(qc))
    from repro.models.transformer import init_attn_cache
    fc = init_attn_cache(cfg, B, C)
    bytes_f = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(fc))
    # reduced config has head_dim=16, so the fp32 scale adds 4B/16 elems;
    # at production head_dim>=64 the ratio is ~0.51
    assert bytes_q < 0.66 * bytes_f, (bytes_q, bytes_f)
    hd = cfg.head_dim_
    prod_ratio = (1 * 128 + 4) / (2 * 128)   # int8 + scale vs bf16, hd=128
    assert prod_ratio < 0.52


def test_int8_cache_end_to_end_decode():
    """Full prefill+decode with kv_cache_dtype=int8: logits track the bf16
    cache within quantization noise for dense AND moe families."""
    import dataclasses
    from repro.configs import get_config
    from repro.models.model import (decode_step, forward, init_decode_state,
                                    make_batch, prefill)
    from repro.models.params import init_params

    for arch in ("qwen1.5-4b", "granite-moe-1b-a400m"):
        cfg = get_config(arch, reduced=True)
        params = init_params(cfg, jax.random.PRNGKey(0))
        B, S, Sp = 1, 16, 12
        batch = make_batch(cfg, B, S)
        logits_full, _, _ = forward(params, cfg, batch, mode="train")

        cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
        state = init_decode_state(cfg8, B, max_seq=S)
        assert state["layer_caches"]["k"].dtype == jnp.int8
        lg, state = prefill(params, cfg8, {"tokens": batch["tokens"][:, :Sp]},
                            state)
        errs = [float(jnp.abs(lg - logits_full[:, Sp - 1]).max())]
        for i in range(Sp, S):
            lg, state = decode_step(params, cfg8,
                                    batch["tokens"][:, i:i + 1],
                                    jnp.full((B,), i, jnp.int32), state)
            errs.append(float(jnp.abs(lg - logits_full[:, i]).max()))
        assert max(errs) < 0.25, (arch, errs)  # int8 noise, not divergence
