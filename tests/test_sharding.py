"""Sharding rule engine: greedy assignment, divisibility fallback, and the
param-spec coverage of every assigned architecture on the production mesh
shapes (AbstractMesh -- no devices needed)."""

import jax
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import ARCH_NAMES, get_config
from repro.models.params import param_table
from repro.parallel.sharding import (ACTIVATION_RULES, PARAM_RULES,
                                     spec_for)

# AbstractMesh's signature changed across JAX releases: newer versions take
# positional (axis_sizes, axis_names), current 0.4.x takes one shape tuple of
# (name, size) pairs.
def _abstract_mesh(sizes, names):
    try:
        return AbstractMesh(tuple(zip(names, sizes)))
    except TypeError:
        return AbstractMesh(tuple(sizes), tuple(names))


MESH1 = _abstract_mesh((16, 16), ("data", "model"))
MESH2 = _abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def test_tp_dims_go_to_model():
    spec = spec_for((8192, 64, 128), ("embed", "heads", "head_dim"), MESH1,
                    PARAM_RULES)
    assert spec == P("data", "model", None)


def test_divisibility_fallback_replicates():
    # 40 experts on a 16-wide model axis -> replicate, ffn takes model
    spec = spec_for((40, 1536, 512), ("experts", "embed", "expert_ffn"),
                    MESH1, PARAM_RULES)
    assert spec == P(None, "data", "model")
    # 32 experts divide -> experts get model, ffn falls back to replicated
    spec = spec_for((32, 1024, 512), ("experts", "embed", "expert_ffn"),
                    MESH1, PARAM_RULES)
    assert spec == P("model", "data", None)


def test_no_axis_reuse_within_tensor():
    spec = spec_for((1024, 1024), ("embed", "embed"), MESH1, PARAM_RULES)
    assert spec == P("data", None)  # second dim cannot reuse "data"


def test_batch_spans_pod_and_data():
    spec = spec_for((256, 4096), ("batch", None), MESH2, ACTIVATION_RULES)
    assert spec == P(("pod", "data"), None)
    # batch=1 (long_500k): indivisible -> replicated
    spec = spec_for((1, 524288), ("batch", None), MESH2, ACTIVATION_RULES)
    assert spec == P(None, None)


def test_kv_heads_indivisible_fallback():
    # kv=8 on model=16 -> replicated (GQA small-kv case)
    spec = spec_for((2048, 8, 128), ("embed", "kv_heads", "head_dim"),
                    MESH1, PARAM_RULES)
    assert spec == P("data", None, None)


def test_every_arch_param_table_shardable_both_meshes():
    """spec_for must succeed (possibly replicating) for EVERY parameter of
    EVERY assigned arch on both production meshes, and every TP-eligible
    matrix of the dense archs must actually get the model axis."""
    for arch in ARCH_NAMES:
        cfg = get_config(arch)
        for mesh in (MESH1, MESH2):
            for path, ps in param_table(cfg).items():
                spec = spec_for(ps.shape, ps.logical, mesh, PARAM_RULES)
                assert len(spec) == len(ps.shape), (arch, path)
                # no axis used twice
                used = [a for a in jax.tree.leaves(tuple(spec))
                        if a is not None]
                flat = []
                for a in used:
                    flat.extend(a if isinstance(a, tuple) else (a,))
                assert len(flat) == len(set(flat)), (arch, path, spec)


def test_dense_ffn_sharded_on_model():
    cfg = get_config("deepseek-67b")
    t = param_table(cfg)
    spec = spec_for(t["layers/mlp/w_gate"].shape,
                    t["layers/mlp/w_gate"].logical, MESH1, PARAM_RULES)
    assert "model" in str(spec)
