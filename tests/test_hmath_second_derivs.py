"""hmath second-derivative coverage: every exported smooth op's hDual
propagation vs ``jax.hessian`` of the jnp-native function on random
in-domain points (satellite of the CurvatureEngine PR).

Each op is composed as f(x) = op(scale * <w, x> + shift) so the Hessian
op''(z) * scale^2 * w w^T is dense -- exercising the chain rule's
g''(u) u_i u_j cross terms, not just the diagonal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.hmath as hm
from repro.core.api import hessian as chess_hessian

# name -> (hmath op, jnp-native op, in-domain z range)
CASES = {
    "sin": (hm.sin, jnp.sin, (-1.5, 1.5)),
    "cos": (hm.cos, jnp.cos, (-1.5, 1.5)),
    "tan": (hm.tan, jnp.tan, (-1.0, 1.0)),
    "exp": (hm.exp, jnp.exp, (-1.5, 1.5)),
    "log": (hm.log, jnp.log, (0.5, 3.0)),
    "sqrt": (hm.sqrt, jnp.sqrt, (0.5, 3.0)),
    "tanh": (hm.tanh, jnp.tanh, (-1.5, 1.5)),
    "sigmoid": (hm.sigmoid, jax.nn.sigmoid, (-2.0, 2.0)),
    "asin": (hm.asin, jnp.arcsin, (-0.8, 0.8)),
    "acos": (hm.acos, jnp.arccos, (-0.8, 0.8)),
    "atan": (hm.atan, jnp.arctan, (-1.5, 1.5)),
    "sinh": (hm.sinh, jnp.sinh, (-1.5, 1.5)),
    "cosh": (hm.cosh, jnp.cosh, (-1.5, 1.5)),
    "erf": (hm.erf, jax.scipy.special.erf, (-1.5, 1.5)),
    "log1p": (hm.log1p, jnp.log1p, (-0.5, 2.0)),
    "expm1": (hm.expm1, jnp.expm1, (-1.5, 1.5)),
    "square": (hm.square, jnp.square, (-2.0, 2.0)),
    "abs": (hm.abs, jnp.abs, (0.5, 2.5)),       # away from the kink
    "pow2.5": (lambda u: hm.pow(u, 2.5), lambda z: z ** 2.5, (0.5, 2.5)),
    "recip": (lambda u: 1.0 / u, lambda z: 1.0 / z, (0.5, 2.5)),
}

N = 4


def _point(name, seed_extra=0):
    rng = np.random.RandomState((abs(hash(name)) + seed_extra) % 2 ** 31)
    w = jnp.asarray(rng.uniform(0.2, 0.5, N), jnp.float32)
    x = jnp.asarray(rng.uniform(-1.0, 1.0, N), jnp.float32)
    return w, x


@pytest.mark.parametrize("name", sorted(CASES))
@pytest.mark.parametrize("csize", [1, 2, 4])
def test_second_derivatives_match_jax_hessian(name, csize):
    hf, jf, (lo, hi) = CASES[name]
    w, x = _point(name, csize)
    # scale/shift chosen so z = scale*<w,x> + shift stays inside [lo, hi]
    wsum = float(jnp.abs(w).sum())
    scale = (hi - lo) / (2.0 * wsum)
    shift = (hi + lo) / 2.0

    def f_h(u):
        return hf(hm.dot_const(u, w) * scale + shift)

    def f_j(z):
        return jf(jnp.dot(z, w) * scale + shift)

    H = chess_hessian(f_h, x, csize=csize, symmetric=True)
    H_ref = jax.hessian(f_j)(x)
    np.testing.assert_allclose(
        np.asarray(H), np.asarray(H_ref), rtol=2e-3,
        atol=2e-3 * (1.0 + float(jnp.abs(H_ref).max())), err_msg=name)


@pytest.mark.parametrize("name", ["maximum", "minimum", "where"])
def test_branch_ops_second_derivatives(name):
    """Branch-select ops: second derivatives follow the taken branch."""
    w, x = _point(name)

    if name == "maximum":
        f_h = lambda u: hm.maximum(hm.square(hm.dot_const(u, w)) + 2.0,
                                   hm.dot_const(u, w))
        f_j = lambda z: jnp.maximum(jnp.square(jnp.dot(z, w)) + 2.0,
                                    jnp.dot(z, w))
    elif name == "minimum":
        f_h = lambda u: hm.minimum(hm.exp(hm.dot_const(u, w)) + 5.0,
                                   hm.square(hm.dot_const(u, w)))
        f_j = lambda z: jnp.minimum(jnp.exp(jnp.dot(z, w)) + 5.0,
                                    jnp.square(jnp.dot(z, w)))
    else:
        f_h = lambda u: hm.where(hm.dot_const(u, w) > 10.0,
                                 hm.dot_const(u, w),
                                 hm.sin(hm.dot_const(u, w)))
        f_j = lambda z: jnp.where(jnp.dot(z, w) > 10.0, jnp.dot(z, w),
                                  jnp.sin(jnp.dot(z, w)))

    H = chess_hessian(f_h, x, csize=2, symmetric=True)
    H_ref = jax.hessian(f_j)(x)
    np.testing.assert_allclose(
        np.asarray(H), np.asarray(H_ref), rtol=2e-3,
        atol=2e-3 * (1.0 + float(jnp.abs(H_ref).max())), err_msg=name)
