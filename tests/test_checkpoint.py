"""Checkpoint tests: atomic publish, torn-state recovery, retention GC,
restore-with-resharding, async manager."""

import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, latest_step,
                              restore_checkpoint, save_checkpoint)


def make_tree(seed=0):
    rng = np.random.RandomState(seed)
    return {"params": {"w": jnp.asarray(rng.randn(8, 4), jnp.float32),
                       "b": jnp.asarray(rng.randn(4), jnp.float32)},
            "opt": {"m": {"w": jnp.zeros((8, 4)), "b": jnp.ones((4,))}},
            "step": jnp.asarray(7, jnp.int32)}


def assert_tree_equal(a, b):
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(
        np.asarray(x), np.asarray(y)), a, b)


def test_roundtrip(tmp_path):
    tree = make_tree()
    save_checkpoint(str(tmp_path), 7, tree)
    assert latest_step(str(tmp_path)) == 7
    target = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                          tree)
    out = restore_checkpoint(str(tmp_path), 7, target)
    assert_tree_equal(tree, out)


def test_atomicity_torn_tmp_ignored(tmp_path):
    tree = make_tree()
    save_checkpoint(str(tmp_path), 1, tree)
    # simulate a crash mid-save at step 2: leave only a .tmp dir
    os.makedirs(tmp_path / "step_2.tmp")
    with open(tmp_path / "step_2.tmp" / "meta.json", "w") as f:
        f.write("{}")
    assert latest_step(str(tmp_path)) == 1


def test_latest_pointer_torn_state(tmp_path):
    tree = make_tree()
    save_checkpoint(str(tmp_path), 3, tree)
    # LATEST points to a checkpoint dir that vanished -> treated as absent
    shutil.rmtree(tmp_path / "step_3")
    assert latest_step(str(tmp_path)) is None


def test_restore_with_resharding(tmp_path):
    """Save replicated, restore with an explicit (1,1)-mesh NamedSharding --
    the elastic-restart code path."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_test_mesh

    tree = make_tree()
    save_checkpoint(str(tmp_path), 5, tree)
    mesh = make_test_mesh((1, 1), ("data", "model"))
    shard = NamedSharding(mesh, P("data", "model"))
    target = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                          tree)
    shardings = jax.tree.map(
        lambda x: NamedSharding(mesh, P()) if x.ndim != 2 else shard, tree)
    out = restore_checkpoint(str(tmp_path), 5, target, shardings)
    assert_tree_equal(tree, out)
    assert out["params"]["w"].sharding == shard


def test_manager_gc_and_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save_async(s, make_tree(s))
    mgr.join()
    steps = sorted(int(n.split("_")[1]) for n in os.listdir(tmp_path)
                   if n.startswith("step_"))
    assert steps == [3, 4]
    assert mgr.latest() == 4
    out = mgr.restore(4, jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), make_tree(4)))
    assert_tree_equal(make_tree(4), out)


def test_shape_mismatch_rejected(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"w": jnp.zeros((4,))})
    with pytest.raises(AssertionError):
        restore_checkpoint(str(tmp_path), 1,
                           {"w": jax.ShapeDtypeStruct((5,), jnp.float32)})
