"""Newton-CG on the paper's test functions: both HVP engines must drive the
gradient to ~0, and the chunked-hDual engine must match fwdrev trajectories.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import testfns
from repro.optim.newton_cg import newton_cg


@pytest.mark.parametrize("engine", ["chessfad", "fwdrev"])
def test_rosenbrock_minimized(engine):
    n = 8
    x0 = jnp.zeros((n,)) - 0.5
    x, info = newton_cg(testfns.rosenbrock, x0, engine=engine, csize=2,
                        max_outer=80, cg_iters=30)
    # global minimum at x = 1
    np.testing.assert_allclose(np.asarray(x), np.ones(n), atol=1e-3)
    assert info["trajectory"][-1]["f"] < 1e-6


def test_engines_agree_on_quadratic():
    n = 12
    f = testfns.make_fletcher_powell(n)
    x0 = testfns.sample_point(n, seed=3) * 0.1
    xa, ia = newton_cg(f, x0, engine="chessfad", csize=4, max_outer=30)
    xb, ib = newton_cg(f, x0, engine="fwdrev", max_outer=30)
    # both must reach a stationary point of the same basin; FP's +-100
    # integer coefficients put gradient scales at ~1e4, so the criterion
    # is relative to the starting gradient
    g0 = ia["trajectory"][0]["gnorm"]
    assert ia["trajectory"][-1]["gnorm"] < 1e-4 * g0
    assert ib["trajectory"][-1]["gnorm"] < 1e-4 * g0
    np.testing.assert_allclose(np.asarray(f(xa)), np.asarray(f(xb)),
                               rtol=1e-2, atol=1e-3)


def test_descent_monotone():
    n = 6
    x0 = testfns.sample_point(n, seed=1)
    _, info = newton_cg(testfns.ackley, x0, engine="fwdrev", max_outer=20)
    fs = [t["f"] for t in info["trajectory"]]
    assert all(b <= a + 1e-9 for a, b in zip(fs, fs[1:]))
