"""CHESSFAD public API vs JAX oracles: full Hessians, HVPs, the L0/L1/L2
batched schedules (paper Algs. 2-10), and the §5 op-count bookkeeping."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import api, ref, testfns
from repro.core.api import (batched_hvp, chunk_pairs, gradient, hessian, hvp,
                            num_chunk_evals, optimal_csize)

FN = {
    "rosenbrock": lambda n: testfns.rosenbrock,
    "ackley": lambda n: testfns.ackley,
    "fletcher_powell": testfns.make_fletcher_powell,
}


@pytest.mark.parametrize("fname", sorted(FN))
@pytest.mark.parametrize("n,csize", [(4, 1), (8, 2), (8, 8), (6, 4)])
@pytest.mark.parametrize("symmetric", [False, True])
def test_hessian_matches_jax(fname, n, csize, symmetric):
    f = FN[fname](n)
    a = testfns.sample_point(n, seed=n + csize)
    H = hessian(f, a, csize=csize, symmetric=symmetric)
    H_ref = ref.hessian_fwdrev(f, a)
    np.testing.assert_allclose(np.asarray(H), np.asarray(H_ref),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("fname", sorted(FN))
@pytest.mark.parametrize("n,csize", [(8, 2), (8, 4), (12, 3)])
@pytest.mark.parametrize("symmetric", [False, True])
def test_hvp_matches_jax(fname, n, csize, symmetric):
    f = FN[fname](n)
    a = testfns.sample_point(n, seed=1)
    v = testfns.sample_point(n, seed=2)
    r = hvp(f, a, v, csize=csize, symmetric=symmetric)
    r_ref = ref.hvp_fwdrev(f, a, v)
    np.testing.assert_allclose(np.asarray(r), np.asarray(r_ref),
                               rtol=2e-3, atol=2e-3)


def test_gradient_matches_jax():
    n = 10
    f = FN["ackley"](n)
    a = testfns.sample_point(n, seed=3)
    g = gradient(f, a, csize=4)
    np.testing.assert_allclose(np.asarray(g),
                               np.asarray(jax.grad(f)(a)), rtol=1e-3,
                               atol=1e-4)


@pytest.mark.parametrize("level", ["L0", "L1", "L2"])
def test_batched_levels_agree(level):
    n, m, csize = 8, 6, 2
    f = FN["rosenbrock"](n)
    rng = np.random.RandomState(0)
    A = jnp.asarray(rng.uniform(-2, 2, (m, n)), jnp.float32)
    V = jnp.asarray(rng.randn(m, n), jnp.float32)
    out = batched_hvp(f, A, V, csize=csize, level=level)
    want = jnp.stack([ref.hvp_fwdrev(f, A[i], V[i]) for i in range(m)])
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_hvp_fwdfwd_oracle_agrees():
    n = 8
    f = FN["rosenbrock"](n)
    a = testfns.sample_point(n, seed=5)
    v = testfns.sample_point(n, seed=6)
    np.testing.assert_allclose(np.asarray(ref.hvp_fwdfwd(f, a, v)),
                               np.asarray(ref.hvp_fwdrev(f, a, v)),
                               rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# §5 bookkeeping
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(st.sampled_from([1, 2, 4, 8]), st.integers(1, 8))
def test_chunk_count_formulas(csize, mult):
    """Paper §5: symmetric scheme evaluates n*(n/csize+1)/2 chunks; the
    plain scheme n^2/csize, when csize | n."""
    n = csize * mult
    assert num_chunk_evals(n, csize, False) == n * n // csize
    assert num_chunk_evals(n, csize, True) == n * (n // csize + 1) // 2


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 6))
def test_optimal_csize_near_sqrt_half_n(k):
    """Paper §5: scalar multiplies of SCHUNK-HESS are minimized at
    csize = sqrt(n/2); for n = 2^(2k+1) that's exactly 2^k."""
    n = 2 ** (2 * k + 1)
    assert optimal_csize(n) == 2 ** k


def test_chunk_pairs_cover_upper_triangle():
    n, csize = 8, 2
    pairs = chunk_pairs(n, csize, symmetric=True)
    seen = set()
    for i, c in pairs:
        for l in range(csize):
            seen.add((int(i), int(c) + l))
    # every (i, j) with chunk(j) >= chunk(i) must be covered
    for i in range(n):
        for j in range((i // csize) * csize, n):
            assert (i, j) in seen
