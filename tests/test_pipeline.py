"""Pipeline parallelism: GPipe schedule must equal the sequential layer
stack exactly (forward AND gradients), on fake devices; plus an elastic
save-on-mesh-A / restore-on-mesh-B checkpoint test."""

from tests.test_distributed import run_with_fake_devices


def test_pipeline_matches_sequential():
    run_with_fake_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from repro.training.pipeline import pipeline_forward, stack_stages

        from repro.compat import make_mesh as compat_make_mesh
        mesh = compat_make_mesh((4, 2), ("pipe", "data"))
        L, B, D = 8, 16, 32
        rng = np.random.RandomState(0)
        params = {"w": jnp.asarray(rng.randn(L, D, D) * 0.1, jnp.float32),
                  "b": jnp.asarray(rng.randn(L, D) * 0.1, jnp.float32)}
        x = jnp.asarray(rng.randn(B, D), jnp.float32)

        def body(lp, h):
            return jnp.tanh(h @ lp["w"] + lp["b"])

        def sequential(params, x):
            def sb(h, lp):
                return body(lp, h), None
            out, _ = jax.lax.scan(sb, x, params)
            return out

        ref = sequential(params, x)
        staged = stack_stages(params, 4)
        out = pipeline_forward(body, staged, x, mesh, n_microbatches=4)
        assert float(jnp.abs(out - ref).max()) < 1e-5, "forward mismatch"

        # gradients flow through ppermute identically
        g_ref = jax.grad(lambda p: sequential(p, x).sum())(params)
        g_pp = jax.grad(lambda sp: pipeline_forward(
            body, sp, x, mesh, n_microbatches=4).sum())(staged)
        from repro.training.pipeline import stack_stages as ss
        g_ref_staged = ss(g_ref, 4)
        err = max(float(jnp.abs(a - b).max()) for a, b in
                  zip(jax.tree.leaves(g_pp), jax.tree.leaves(g_ref_staged)))
        assert err < 1e-4, f"grad mismatch {err}"
        print("PIPELINE_OK")
    """)


def test_elastic_restart_across_meshes():
    run_with_fake_devices("""
        import tempfile, jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import save_checkpoint, restore_checkpoint

        rng = np.random.RandomState(0)
        tree = {"w": jnp.asarray(rng.randn(16, 8), jnp.float32),
                "m": jnp.asarray(rng.randn(16, 8), jnp.float32)}
        from repro.compat import make_mesh as compat_make_mesh
        mesh_a = compat_make_mesh((2, 4), ("data", "model"))
        tree_a = jax.tree.map(lambda x: jax.device_put(
            x, NamedSharding(mesh_a, P("data", "model"))), tree)
        with tempfile.TemporaryDirectory() as d:
            save_checkpoint(d, 3, tree_a)
            # 'cluster shrank': restore onto a DIFFERENT mesh topology
            mesh_b = compat_make_mesh((4, 2), ("data", "model"))
            target = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
            shards = jax.tree.map(lambda x: NamedSharding(
                mesh_b, P("model", "data")), tree)
            out = restore_checkpoint(d, 3, target, shards)
            for k in tree:
                np.testing.assert_array_equal(np.asarray(out[k]),
                                              np.asarray(tree[k]))
                assert out[k].sharding.mesh.shape["data"] == 4
        print("ELASTIC_OK")
    """)
