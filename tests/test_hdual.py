"""HDual engine unit + property tests: every overloaded op must propagate
first/second derivatives identically to JAX's own AD (the oracle)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

import repro.core.hmath as hm
from repro.core.api import eval_chunk
from repro.core.hdual import HDual, seed_point

jax.config.update("jax_enable_x64", False)


def hdual_hessian_chunk(f, a, i, cstart, csize):
    out = eval_chunk(f, jnp.asarray(a, jnp.float32), i, cstart, csize)
    return np.asarray(out.val), np.asarray(out.di), np.asarray(out.dj), \
        np.asarray(out.dij)


def oracle(f, a, i, cstart, csize):
    a = jnp.asarray(a, jnp.float32)
    g = jax.grad(f)(a)
    H = jax.hessian(f)(a)
    cols = np.arange(cstart, cstart + csize)
    return (np.asarray(f(a)), np.asarray(g[i]), np.asarray(g[cols]),
            np.asarray(H[i, cols]))


FUNCS = {
    "poly": lambda x: (x ** 3).sum(0) + (x[0] * x[1]) * 2.0 - x[2],
    "trig": lambda x: hm.sin(x[0] * x[1]) + hm.cos(x).sum(0),
    "exp": lambda x: hm.exp(x * 0.3).sum(0) * hm.sigmoid(x[1]),
    "div": lambda x: (x[0] / (x[1] + 10.0)) + (1.0 / (x * x + 3.0)).sum(0),
    "mixed": lambda x: hm.tanh(x[0]) * hm.sqrt(x[1] * x[1] + 1.0)
    + hm.log(x[2] * x[2] + 2.0),
    "minmax": lambda x: hm.maximum(x[0] * x[0], x[1] + 5.0)
    + hm.abs(x[2] + 7.0),
    "pow": lambda x: (x ** 4).sum(0) + x[1] ** 3,
}


@pytest.mark.parametrize("name", sorted(FUNCS))
@pytest.mark.parametrize("i,cstart,csize", [(0, 0, 1), (2, 0, 4), (1, 2, 2)])
def test_ops_vs_oracle(name, i, cstart, csize):
    f = FUNCS[name]
    rng = np.random.RandomState(hash(name) % 2 ** 31)
    a = rng.uniform(-1.5, 1.5, size=(4,)).astype(np.float32)
    got = hdual_hessian_chunk(f, a, i, cstart, csize)
    want = oracle(f, a, i, cstart, csize)
    for g, w, what in zip(got, want, ["val", "di", "dj", "dij"]):
        np.testing.assert_allclose(g, w, rtol=2e-4, atol=2e-4,
                                   err_msg=f"{name}/{what}")


@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(-2.0, 2.0), min_size=4, max_size=4),
       st.integers(0, 3), st.integers(0, 1))
def test_property_second_derivative_symmetry(vals, i, chunk_idx):
    """H[i,j] computed via row i must equal H[j,i] via row j (hDual engine
    must satisfy Schwarz symmetry for smooth f)."""
    a = np.asarray(vals, np.float32)
    f = FUNCS["trig"]
    csize = 2
    cstart = chunk_idx * 2
    _, _, _, dij = hdual_hessian_chunk(f, a, i, cstart, csize)
    for l, j in enumerate(range(cstart, cstart + csize)):
        _, _, _, dji = hdual_hessian_chunk(f, a, j, (i // csize) * csize,
                                           csize)
        np.testing.assert_allclose(dij[l], dji[i % csize], rtol=1e-3,
                                   atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.floats(-1.5, 1.5), min_size=3, max_size=3),
       st.lists(st.floats(-1.5, 1.5), min_size=3, max_size=3))
def test_property_linearity_in_seed(a_vals, _unused):
    """dij is linear in the dj seed: seeding e_j + e_k in one slot equals
    the sum of separate seeds (the superposition the chunk layout relies
    on)."""
    f = FUNCS["poly"]
    a = jnp.asarray(a_vals, jnp.float32)
    y = seed_point(a, 0, 0, 3)
    full = f(y)
    # manual combined seed: dj slot = e_1 + e_2
    comb = HDual(y.val, y.di,
                 y.dj[..., 1:2] + y.dj[..., 2:3],
                 y.dij[..., :1])
    out = f(comb)
    np.testing.assert_allclose(np.asarray(out.dij[..., 0]),
                               np.asarray(full.dij[..., 1]
                                          + full.dij[..., 2]),
                               rtol=1e-4, atol=1e-5)


def test_integer_power_bitwise_stable():
    a = jnp.asarray([1.5, -0.5], jnp.float32)
    y = seed_point(a, 0, 0, 2)
    assert np.allclose(np.asarray((y ** 2).val), np.asarray((y * y).val))
    assert np.allclose(np.asarray((y ** 3).dij),
                       np.asarray((y * y * y).dij), rtol=1e-6)


def test_comparisons_act_on_primal():
    a = jnp.asarray([2.0, -3.0], jnp.float32)
    y = seed_point(a, 0, 0, 1)
    assert bool((y[0] > y[1]))
    assert bool((y[1] <= 0.0))


def test_reshape_sum_roundtrip():
    a = jnp.arange(6, dtype=jnp.float32)
    y = seed_point(a, 1, 0, 2)
    z = y.reshape(2, 3).sum(axis=(0, 1))
    np.testing.assert_allclose(np.asarray(z.val), a.sum())
    np.testing.assert_allclose(np.asarray(z.dj),
                               np.asarray(y.dj.sum(0)))


EXTRA_FUNCS = {
    "asin": lambda x: hm.asin(x[0] * 0.4) + hm.acos(x[1] * 0.4),
    "atan": lambda x: hm.atan(x).sum(0) * hm.atan(x[0] * x[1]),
    "hyper": lambda x: hm.sinh(x[0]) * hm.cosh(x[1]) + hm.sinh(x).sum(0),
    "erf": lambda x: hm.erf(x[0]) + hm.erf(x * 0.5).sum(0),
    "log1p": lambda x: hm.log1p(x[0] * x[0]) + hm.expm1(x[1] * 0.3),
}


@pytest.mark.parametrize("name", sorted(EXTRA_FUNCS))
@pytest.mark.parametrize("i,cstart,csize", [(0, 0, 2), (1, 2, 2)])
def test_extended_ops_vs_oracle(name, i, cstart, csize):
    f = EXTRA_FUNCS[name]
    rng = np.random.RandomState(abs(hash(name)) % 2 ** 31)
    a = rng.uniform(-1.2, 1.2, size=(4,)).astype(np.float32)
    got = hdual_hessian_chunk(f, a, i, cstart, csize)
    want = oracle(f, a, i, cstart, csize)
    for g, w, what in zip(got, want, ["val", "di", "dj", "dij"]):
        np.testing.assert_allclose(g, w, rtol=5e-4, atol=5e-4,
                                   err_msg=f"{name}/{what}")
