"""Data pipeline: step-keyed determinism (the fault-tolerance contract) and
shape/dtype correctness."""

import jax.numpy as jnp
import numpy as np

from repro.configs import SHAPES, get_config
from repro.data import SyntheticTokens, global_batch_at


def test_deterministic_across_restart():
    ds1 = SyntheticTokens(vocab_size=1000, batch=4, seq=64, seed=3)
    ds2 = SyntheticTokens(vocab_size=1000, batch=4, seq=64, seed=3)
    for step in (0, 5, 117):
        np.testing.assert_array_equal(np.asarray(ds1.batch_at(step)),
                                      np.asarray(ds2.batch_at(step)))


def test_steps_differ_and_rows_differ():
    ds = SyntheticTokens(vocab_size=1000, batch=4, seq=64, seed=0)
    b0, b1 = np.asarray(ds.batch_at(0)), np.asarray(ds.batch_at(1))
    assert (b0 != b1).any()
    assert (b0[0] != b0[1]).any()


def test_tokens_in_range():
    ds = SyntheticTokens(vocab_size=257, batch=2, seq=512, seed=1)
    b = np.asarray(ds.batch_at(0))
    assert b.dtype == np.int32
    assert b.min() >= 0 and b.max() < 257


def test_global_batch_for_frontends():
    cfg = get_config("internvl2-1b", reduced=True)
    shape = SHAPES["train_4k"]
    import dataclasses
    small = dataclasses.replace(shape, seq_len=32, global_batch=2)
    batch = global_batch_at(cfg, small, step=0)
    assert batch["tokens"].shape == (2, 32 - cfg.frontend_len)
    assert batch["patches"].shape == (2, cfg.frontend_len, cfg.d_model)
