"""Model-zoo conformance: EVERY configs/ architecture must plan and execute
hvp, diag, and ggn through ``engine.plan()`` on its tiny-ified instance,
match the direct pytree oracles at 1e-6 normalized error, and hit the
executable cache with ZERO retraces on re-planning (trace-counter witness).

This is the PR 7 acceptance gate: the zoo spans every family (dense, moe,
ssm, hybrid, vlm, encdec), so a pass here means the pytree workloads hold
for arbitrary LM parameter structures, not just toy dicts."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine
from repro.configs.base import ARCH_NAMES, get_config
from repro.core.curvature import (empirical_fisher_vp, ggn_hvp,
                                  hutchinson_diag, pytree_hvp)
from repro.models.model import make_batch
from repro.models.params import init_params
from repro.models.targets import diag_spectrum, lm_curvature_targets
from repro.models.kv_quant import choose_kv_cache_dtype, kv_sensitivity

# the zoo sweep compiles every architecture x workload: minutes, not
# seconds.  CI runs it in its own job; the tier-1 lane deselects it with
# ``-m "not slow"`` (pyproject registers the marker).
pytestmark = pytest.mark.slow

BATCH, SEQ = 2, 16          # seq 16 keeps the vlm configs' token span >= 8
N_PROBES, CSIZE = 2, 2

_CASES: dict = {}


def _case(name):
    """One tiny-ified zoo instance per arch, built once per session: the
    reduced config, its curvature targets, params, and the shared plan."""
    if name not in _CASES:
        cfg = get_config(name, reduced=True)
        batch = make_batch(cfg, BATCH, SEQ, key=jax.random.PRNGKey(7))
        tgt = lm_curvature_targets(cfg, batch)
        params = init_params(cfg, jax.random.PRNGKey(0))
        opts = {"n_probes": N_PROBES, **tgt.plan_options()}
        p = engine.plan(tgt.loss, None, csize=CSIZE,
                        backend="pytree_fwdrev", options=opts)
        _CASES[name] = (cfg, tgt, params, p, opts)
    return _CASES[name]


def _nerr(got, want):
    g = np.concatenate([np.asarray(l, np.float64).ravel()
                        for l in jax.tree.leaves(got)])
    w = np.concatenate([np.asarray(l, np.float64).ravel()
                        for l in jax.tree.leaves(want)])
    return float(np.linalg.norm(g - w) / (np.linalg.norm(w) + 1e-30))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_zoo_hvp_diag_ggn_parity_and_zero_retrace(name):
    cfg, tgt, params, p, opts = _case(name)
    v = jax.tree.map(lambda l: jnp.full(l.shape, 0.01, l.dtype), params)
    key = jax.random.PRNGKey(3)

    got_hvp = p.hvp(params, v)
    want_hvp = jax.jit(lambda a, vv: pytree_hvp(tgt.loss, a, vv))(params, v)
    assert _nerr(got_hvp, want_hvp) < 1e-6

    got_diag = p.diag(params, key)
    want_diag = jax.jit(lambda a, k: hutchinson_diag(
        tgt.loss, a, k, n_probes=N_PROBES, csize=CSIZE))(params, key)
    assert _nerr(got_diag, want_diag) < 1e-6

    got_ggn = p.ggn(params, v)
    want_ggn = jax.jit(lambda a, vv: ggn_hvp(
        tgt.model_fn, tgt.head_loss, a, vv))(params, v)
    assert _nerr(got_ggn, want_ggn) < 1e-6

    # zero retraces: re-planning the same signature and re-executing every
    # workload must not trace again (process-wide executable cache)
    counts = {w: engine.trace_count(p.cache_key(w, "pytree_fwdrev"))
              for w in ("hvp", "diag", "ggn")}
    assert all(c == 1 for c in counts.values()), counts
    p2 = engine.plan(tgt.loss, None, csize=CSIZE,
                     backend="pytree_fwdrev", options=dict(opts))
    p2.hvp(params, v)
    p2.diag(params, key)
    p2.ggn(params, v)
    for w, c in counts.items():
        assert engine.trace_count(p2.cache_key(w, "pytree_fwdrev")) == c


def test_zoo_fisher_parity_and_kv_policy():
    """Fisher route parity on one arch, plus the end-to-end curvature ->
    KV-cache quantization policy pipeline."""
    cfg, tgt, params, p, _ = _case("qwen1.5-4b")
    v = jax.tree.map(lambda l: jnp.full(l.shape, 0.01, l.dtype), params)
    got = p.fisher(params, v)
    want = jax.jit(lambda a, vv: empirical_fisher_vp(
        tgt.per_example_fn, a, vv))(params, v)
    assert _nerr(got, want) < 1e-6

    spectrum = diag_spectrum(p.diag(params, jax.random.PRNGKey(5)))
    sens = kv_sensitivity(spectrum)
    assert sorted(sens) == list(range(cfg.num_layers))
    policy = choose_kv_cache_dtype(sens, int8_budget_frac=0.5)
    assert set(policy.values()) <= {"int8", "bfloat16"}
    assert list(policy.values()).count("int8") == cfg.num_layers // 2
