"""Distributed-path tests on 8 FAKE host devices, run in subprocesses so the
main pytest process keeps its single real device (dry-run rule: only
subprocesses fake device counts).

Covers: shard_map hierarchical gradient sync (fp32 / bf16 / int8-stochastic
cross-pod compression), the distributed CHESSFAD L0/L1 schedules, and a
(2,2,2) multi-pod shard_map train step."""

import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


def run_with_fake_devices(body: str, n: int = 8) -> str:
    prog = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count={n} "
            + os.environ.get("XLA_FLAGS", ""))
    """) + textwrap.dedent(body)
    env = dict(os.environ,
               PYTHONPATH=os.path.join(ROOT, "src")
               + os.pathsep + os.environ.get("PYTHONPATH", ""))
    out = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"STDOUT:{out.stdout}\nSTDERR:{out.stderr}"
    return out.stdout


def test_hierarchical_grad_sync_compression():
    run_with_fake_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from repro.compat import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.parallel.collectives import hierarchical_grad_sync

        mesh = jax.make_mesh((2, 4), ("pod", "data"))
        rng = np.random.RandomState(0)
        g = jnp.asarray(rng.randn(8, 64), jnp.float32)

        def sync(method):
            @partial(shard_map, mesh=mesh, in_specs=P(("pod", "data")),
                     out_specs=P(("pod", "data")), check_vma=False)
            def run(blk):
                return hierarchical_grad_sync(
                    {"g": blk}, data_axis="data", pod_axis="pod",
                    key=jax.random.PRNGKey(0), method=method)["g"]
            return np.asarray(run(g))

        exact = sync("none")
        want = np.broadcast_to(np.asarray(g).mean(0, keepdims=True),
                               g.shape)
        np.testing.assert_allclose(exact, want, rtol=1e-5, atol=1e-6)
        bf16 = sync("bf16")
        np.testing.assert_allclose(bf16, exact, rtol=2e-2, atol=2e-2)
        q8 = sync("int8")
        np.testing.assert_allclose(q8, exact, rtol=0.15,
                                   atol=0.1 * np.abs(exact).max())
        print("SYNC_OK")
    """)


def test_int8_quantization_unbiased():
    run_with_fake_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.parallel.collectives import quantize_int8, dequantize_int8
        x = jnp.linspace(-3.0, 3.0, 64)
        outs = []
        for i in range(512):
            q, s = quantize_int8(x, jax.random.PRNGKey(i))
            outs.append(np.asarray(dequantize_int8(q, s)))
        mean = np.stack(outs).mean(0)
        np.testing.assert_allclose(mean, np.asarray(x), atol=6e-3)
        print("UNBIASED_OK")
    """, n=1)


def test_distributed_chessfad_hvp():
    run_with_fake_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.distributed import (distributed_batched_hvp,
                                            distributed_hvp_rows)
        from repro.core import testfns, ref

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        n, m, csize = 8, 16, 2
        f = testfns.rosenbrock
        rng = np.random.RandomState(0)
        A = jnp.asarray(rng.uniform(-2, 2, (m, n)), jnp.float32)
        V = jnp.asarray(rng.randn(m, n), jnp.float32)
        out = distributed_batched_hvp(mesh, f, A, V, csize=csize)
        want = jnp.stack([ref.hvp_fwdrev(f, A[i], V[i]) for i in range(m)])
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-3, atol=2e-3)
        r = distributed_hvp_rows(mesh, f, A[0], V[0], csize=csize)
        np.testing.assert_allclose(np.asarray(r), np.asarray(want[0]),
                                   rtol=2e-3, atol=2e-3)
        print("DIST_HVP_OK")
    """)


def test_multipod_shard_map_train_step():
    run_with_fake_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.models.params import init_params
        from repro.models.model import make_batch
        from repro.optim import adamw
        from repro.optim.schedule import constant
        from repro.training import TrainState
        from repro.training.steps import make_shard_map_train_step

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = get_config("minitron-4b", reduced=True)
        opt = adamw(constant(1e-3))
        params = init_params(cfg, jax.random.PRNGKey(0))
        state = TrainState(params, opt.init(params),
                           jnp.zeros((), jnp.int32), jax.random.PRNGKey(1))
        step = make_shard_map_train_step(cfg, mesh, opt, compress="bf16")
        batch = make_batch(cfg, 8, 16)
        losses = []
        for i in range(3):
            state, m = step(state, make_batch(cfg, 8, 16,
                                              jax.random.PRNGKey(i)))
            loss = float(m["loss"])
            assert loss == loss
            losses.append(loss)
        print("MULTIPOD_OK", losses)
    """)


def test_gspmd_train_step_on_2d_mesh():
    run_with_fake_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.models.params import init_params, param_specs
        from repro.models.model import make_batch
        from repro.optim import adamw
        from repro.optim.schedule import constant
        from repro.training import TrainState, make_train_step

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = get_config("granite-moe-1b-a400m", reduced=True)
        opt = adamw(constant(1e-3))
        params = init_params(cfg, jax.random.PRNGKey(0))
        specs = param_specs(cfg, mesh)
        params = jax.tree.map(
            lambda p, s: jax.device_put(p, NamedSharding(mesh, s)),
            params, specs)
        state = TrainState(params, opt.init(params),
                           jnp.zeros((), jnp.int32), jax.random.PRNGKey(1))
        step = make_train_step(cfg, mesh, opt)
        batch = make_batch(cfg, 4, 32)
        batch = jax.device_put(batch, NamedSharding(mesh, P("data")))
        state, m = step(state, batch)
        assert float(m["loss"]) == float(m["loss"])
        print("GSPMD_OK", float(m["loss"]))
    """)
