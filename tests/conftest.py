"""Shared fixtures. NOTE: no XLA_FLAGS manipulation here -- smoke tests and
benches must see the single real CPU device; only launch/dryrun.py (run as
its own process) fakes 512 devices."""

import os
import tempfile

import jax
import pytest

# isolate the autotune disk store: tests must neither write the developer's
# real ~/.cache store nor be steered by winners a previous (or ambient)
# store persisted.  Session-scoped (not per-test) so in-process persistence
# tests still see round-trips; set before repro.engine is imported by any
# test module.
os.environ["REPRO_AUTOTUNE_CACHE"] = os.path.join(
    tempfile.mkdtemp(prefix="repro-autotune-test-"), "autotune.json")


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
