"""Shared fixtures. NOTE: no XLA_FLAGS manipulation here -- smoke tests and
benches must see the single real CPU device; only launch/dryrun.py (run as
its own process) fakes 512 devices."""

import jax
import pytest


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
