"""Token-decode engine: continuous batching must produce exactly the tokens a
naive one-request-at-a-time greedy decode produces."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.model import (decode_step, init_decode_state, make_batch,
                                prefill)
from repro.models.params import init_params
from repro.models.decode_engine import ServingEngine


def naive_greedy(params, cfg, prompt, max_new, max_seq=64):
    state = init_decode_state(cfg, 1, max_seq)
    toks = jnp.asarray(prompt[None, :], jnp.int32)
    lg, state = prefill(params, cfg, {"tokens": toks}, state)
    out = [int(jnp.argmax(lg[0]))]
    pos = len(prompt)
    while len(out) < max_new:
        lg, state = decode_step(params, cfg,
                                jnp.asarray([[out[-1]]], jnp.int32),
                                jnp.asarray([pos], jnp.int32), state)
        out.append(int(jnp.argmax(lg[0])))
        pos += 1
    return out


@pytest.mark.parametrize("arch", ["qwen1.5-4b", "mamba2-2.7b"])
def test_engine_matches_naive_decode(arch):
    cfg = get_config(arch, reduced=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab_size, size=int(rng.randint(3, 10)))
               for _ in range(5)]

    eng = ServingEngine(params, cfg, max_batch=2, max_seq=64)
    reqs = [eng.submit(p, max_new_tokens=6) for p in prompts]
    done = eng.run()
    assert len(done) == len(prompts)

    for req, prompt in zip(reqs, prompts):
        want = naive_greedy(params, cfg, np.asarray(prompt, np.int32), 6)
        assert req.out_tokens == want, (req.rid, req.out_tokens, want)


def test_eos_frees_slot_early():
    cfg = get_config("qwen1.5-4b", reduced=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(params, cfg, max_batch=1, max_seq=64)
    p = np.arange(5, dtype=np.int32)
    first = naive_greedy(params, cfg, p, 1)[0]
    r = eng.submit(p, max_new_tokens=50, eos_id=first)
    done = eng.run()
    assert done[0].done and len(done[0].out_tokens) == 1
