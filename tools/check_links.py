#!/usr/bin/env python
"""Fail on broken intra-repo markdown links (CI docs job).

Checks every ``[text](target)`` in the given markdown files/directories:

- relative file targets must exist (resolved against the linking file);
- ``#fragment`` anchors into a markdown file must match one of its heading
  slugs (GitHub slugger: lowercase, punctuation stripped, spaces -> dashes);
- external links (http/https/mailto) are NOT fetched -- this is an
  intra-repo checker, CI must not depend on the network.

Usage:
    python tools/check_links.py README.md ROADMAP.md docs
Exit status 1 if any link is broken, listing every failure.
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

# [text](target) -- target up to the first unescaped ')'; tolerate one
# level of parens in the target (rare in this repo, cheap to allow)
LINK_RE = re.compile(r"\[[^\]]*\]\(([^()\s]+(?:\([^()]*\)[^()\s]*)?)\)")
CODE_FENCE_RE = re.compile(r"^(```|~~~)")
HEADING_RE = re.compile(r"^(#{1,6})\s+(.*)$")
EXTERNAL = ("http://", "https://", "mailto:", "ftp://")


def github_slug(heading: str) -> str:
    """GitHub's anchor slugger: strip markup, lowercase, keep word chars,
    spaces and dashes; spaces -> dashes."""
    text = re.sub(r"`([^`]*)`", r"\1", heading)          # inline code
    text = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", text)  # links -> text
    text = re.sub(r"[*_~]", "", text)                     # emphasis
    text = text.strip().lower()
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def heading_slugs(md_path: Path) -> set:
    """All anchor slugs a markdown file exposes (with -1/-2 dup suffixes)."""
    slugs: set = set()
    counts: dict = {}
    in_fence = False
    for line in md_path.read_text(encoding="utf-8").splitlines():
        if CODE_FENCE_RE.match(line.strip()):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        m = HEADING_RE.match(line)
        if not m:
            continue
        base = github_slug(m.group(2))
        k = counts.get(base, 0)
        counts[base] = k + 1
        slugs.add(base if k == 0 else f"{base}-{k}")
    return slugs


def iter_links(md_path: Path):
    """Yield (lineno, target) for every markdown link outside code fences."""
    in_fence = False
    for lineno, line in enumerate(
            md_path.read_text(encoding="utf-8").splitlines(), start=1):
        if CODE_FENCE_RE.match(line.strip()):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        # strip inline code spans so `[x](y)` examples aren't checked
        stripped = re.sub(r"`[^`]*`", "", line)
        for m in LINK_RE.finditer(stripped):
            yield lineno, m.group(1)


def _rel(md_path: Path, repo_root: Path) -> str:
    try:
        return str(md_path.relative_to(repo_root))
    except ValueError:          # file outside the repo root (absolute arg)
        return str(md_path)


def check_file(md_path: Path, repo_root: Path) -> list:
    errors = []
    for lineno, target in iter_links(md_path):
        if target.startswith(EXTERNAL):
            continue
        path_part, _, fragment = target.partition("#")
        if path_part:
            resolved = (md_path.parent / path_part).resolve()
            if not resolved.exists():
                errors.append(f"{_rel(md_path, repo_root)}:{lineno}: "
                              f"broken link target {target!r}")
                continue
        else:
            resolved = md_path.resolve()
        if fragment and resolved.suffix == ".md" and resolved.is_file():
            if github_slug(fragment) not in heading_slugs(resolved):
                errors.append(f"{_rel(md_path, repo_root)}:{lineno}: "
                              f"missing anchor {target!r}")
    return errors


def main(argv) -> int:
    repo_root = Path(__file__).resolve().parent.parent
    targets = argv or ["README.md", "ROADMAP.md", "docs"]
    md_files: list = []
    for t in targets:
        p = (repo_root / t) if not Path(t).is_absolute() else Path(t)
        if p.is_dir():
            md_files.extend(sorted(p.rglob("*.md")))
        elif p.exists():
            md_files.append(p)
        else:
            print(f"check_links: no such file or directory: {t}",
                  file=sys.stderr)
            return 2
    errors = []
    for md in md_files:
        errors.extend(check_file(md, repo_root))
    if errors:
        print(f"check_links: {len(errors)} broken link(s):", file=sys.stderr)
        for e in errors:
            print(f"  {e}", file=sys.stderr)
        return 1
    print(f"check_links: {len(md_files)} files OK "
          f"({', '.join(_rel(m, repo_root) for m in md_files)})")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
